"""Artifact registry: N loaded ``Program``\\ s keyed by name.

A serving process loads each model artifact once (``Program.load`` —
never re-partitioning) and registers it under a unique name. Engine
ownership stays **per model**: compiled engines and sharded runners
live on each ``Program`` (lazily built, keyed on the resolved
:class:`~repro.core.execution.ExecutionSpec`), so two registered
models never share or evict each other's compilations, and
re-resolving a runner for the same model returns the same object.

Cold start is killed at insert time: ``register``/``load`` accept
``precompile=`` (a :class:`~repro.serve.batcher.BatchPolicy` or
iterable of batch buckets, with ``timesteps=``) and AOT-compile every
serving shape through :meth:`Program.precompile` before the model
takes its first request — the same code path the
:class:`~repro.serve.batcher.MicroBatcher` uses for drain-time
warming.
"""
from __future__ import annotations

from pathlib import Path
from typing import TYPE_CHECKING

from repro.core.execution import (ExecutionSpec, as_spec,
                                  spec_from_legacy_kwargs)
from repro.core.program import Program

if TYPE_CHECKING:                          # pragma: no cover
    from repro.serve.batcher import BatchPolicy


class ProgramRegistry:
    """Name -> loaded :class:`~repro.core.program.Program`."""

    def __init__(self):
        self._programs: dict[str, Program] = {}
        self._policies: dict[str, "BatchPolicy"] = {}

    # -- registration -------------------------------------------------------

    def register(self, name: str, program: Program, *, precompile=None,
                 timesteps: int | None = None,
                 spec: ExecutionSpec | None = None,
                 verify: bool = False,
                 policy: "BatchPolicy | None" = None) -> Program:
        """Register a loaded program; duplicate names are rejected.

        ``precompile=`` AOT-compiles the given batch buckets (padded
        shapes, ``timesteps`` fixing the T axis) for ``spec`` at
        insert time — see :meth:`Program.precompile`.

        ``verify=True`` statically verifies the artifact first
        (:meth:`Program.verify`, DESIGN.md §13) and rejects it with
        ``ValueError`` listing the diagnostics if any checker reports
        an ERROR — the "safe to serve" gate, run before any AOT work.

        ``policy=`` attaches the model's serving
        :class:`~repro.serve.batcher.BatchPolicy` (queue bound, shed /
        deadline behavior, buckets) to the registration, so deployment
        config travels with the model: ``Server``/``AsyncServer``
        resolve it when no per-call override is given.
        """
        if not name:
            raise ValueError("model name must be non-empty")
        if name in self._programs:
            raise ValueError(f"model {name!r} already registered; "
                             "unregister it first to replace")
        if verify:
            report = program.verify()
            if not report.ok:
                raise ValueError(
                    f"model {name!r} failed static verification with "
                    f"{len(report.errors)} error(s):\n"
                    + "\n".join(f"  {d}" for d in report.errors))
        if precompile is not None:
            if timesteps is None:
                raise ValueError("register(precompile=...) needs timesteps= "
                                 "to fix the T axis of the AOT shapes")
            program.precompile(precompile, timesteps, spec)
        self._programs[name] = program
        if policy is not None:
            self._policies[name] = policy
        return program

    def load(self, name: str, path: str | Path, *, precompile=None,
             timesteps: int | None = None,
             spec: ExecutionSpec | None = None,
             verify: bool = False,
             policy: "BatchPolicy | None" = None) -> Program:
        """``Program.load`` an artifact and register it under ``name``
        (statically verifying first when ``verify=True``,
        AOT-precompiling the serving shapes when ``precompile=`` is
        given)."""
        return self.register(name, Program.load(path),
                             precompile=precompile, timesteps=timesteps,
                             spec=spec, verify=verify, policy=policy)

    def unregister(self, name: str) -> Program:
        if name not in self._programs:
            raise KeyError(f"model {name!r} not registered")
        self._policies.pop(name, None)
        return self._programs.pop(name)

    def policy(self, name: str) -> "BatchPolicy | None":
        """The serving policy registered with the model, if any."""
        self.get(name)                     # KeyError on unknown names
        return self._policies.get(name)

    # -- lookup -------------------------------------------------------------

    def get(self, name: str) -> Program:
        try:
            return self._programs[name]
        except KeyError:
            raise KeyError(f"model {name!r} not registered; have "
                           f"{self.names()}") from None

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._programs))

    def __contains__(self, name: str) -> bool:
        return name in self._programs

    def __len__(self) -> int:
        return len(self._programs)

    # -- per-model runners --------------------------------------------------

    def runner(self, name: str, spec: ExecutionSpec | None = None, *,
               sharded: bool | None = None, mesh=None):
        """The model's batch-callable: ``[b, T, n_in] -> (s, v, stats)``.

        Resolves to the program's owned engine (or owned sharded
        runner when ``spec.mesh`` is set) — repeated calls reuse the
        same compiled object, and distinct models own distinct
        engines. The returned callable carries a ``precompile(buckets,
        timesteps)`` hook for AOT warming. ``sharded=``/``mesh=`` are
        the deprecated pre-spec kwargs.
        """
        program = self.get(name)
        if sharded is not None or mesh is not None:
            if spec is not None:
                raise TypeError("pass spec= OR the deprecated sharded=/"
                                "mesh= kwargs, not both")
            spec = spec_from_legacy_kwargs(
                sharded=sharded, mesh=mesh,
                where="ProgramRegistry.runner", stacklevel=3)
        if spec is None:
            return program.run              # default-spec bound method
        spec = as_spec(spec)
        if spec.engine == "jax" and spec.mesh is not None:
            return program.sharded_runner(spec).run

        def call(ext):
            return program.run(ext, spec)

        if spec.engine == "jax":            # nothing to AOT-warm otherwise

            def precompile(batch_sizes, timesteps):
                return program.precompile(batch_sizes, timesteps, spec)

            call.precompile = precompile
        return call
