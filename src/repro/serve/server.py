"""Server loop: drive request streams against a ``ProgramRegistry``.

No HTTP — a :class:`Request` stream is a list of (model, spike train,
arrival time, stream id) records, which is what a transport layer
would produce anyway. The server groups the stream per model
(each model owns one engine and one micro-batch queue), drains every
queue under its :class:`~repro.serve.batcher.BatchPolicy`, and
surfaces p50/p99/throughput metrics as a plain dict.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.batcher import (BatchPolicy, DrainResult, MicroBatcher,
                                 latency_metrics)
from repro.serve.registry import ProgramRegistry


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request: a spike train for a named model."""
    model: str
    ext: np.ndarray                  # binary [T, n_inputs]
    arrival_us: float
    stream: int = 0                  # client-stream tag (FIFO per stream)


class Server:
    """Drains request streams against the registry, one queue per model.

    policy: default :class:`BatchPolicy`; ``policies`` overrides it per
    model name. ``service_model`` (bucket -> us) makes latencies
    deterministic; ``None`` measures real engine calls. ``spec`` (an
    :class:`~repro.core.execution.ExecutionSpec`) routes every model
    through that execution point — e.g. ``ExecutionSpec(mesh="auto")``
    for the owned multi-device runner. ``sharded=``/``mesh=`` are the
    deprecated pre-spec kwargs.
    """

    def __init__(self, registry: ProgramRegistry, *,
                 policy: BatchPolicy | None = None,
                 policies: dict[str, BatchPolicy] | None = None,
                 service_model=None, spec=None, sharded: bool | None = None,
                 mesh=None):
        if sharded is not None or mesh is not None:
            if spec is not None:
                raise TypeError("pass spec= OR the deprecated sharded=/"
                                "mesh= kwargs, not both")
            from repro.core.execution import spec_from_legacy_kwargs
            spec = spec_from_legacy_kwargs(sharded=sharded, mesh=mesh,
                                           where="Server", stacklevel=3)
        self.registry = registry
        self.policy = policy or BatchPolicy()
        self.policies = dict(policies or {})
        self.service_model = service_model
        self.spec = spec
        self.last_results: dict[str, DrainResult] = {}

    def serve(self, stream: list[Request]) -> dict:
        """Serve every request; return the metrics dict.

        The stream may interleave models and client streams; within
        each model requests are served FIFO by arrival time (ties keep
        stream order — the sort is stable).
        """
        by_model: dict[str, list[Request]] = {}
        for r in sorted(stream, key=lambda r: r.arrival_us):
            if r.model not in self.registry:
                raise KeyError(f"request for unregistered model "
                               f"{r.model!r}; have {self.registry.names()}")
            by_model.setdefault(r.model, []).append(r)

        self.last_results = {}
        metrics: dict = {"models": {}}
        for name, reqs in by_model.items():
            runner = self.registry.runner(name, self.spec)
            batcher = MicroBatcher(self.policies.get(name, self.policy),
                                   runner=runner,
                                   service_model=self.service_model)
            ext = np.stack([r.ext for r in reqs])
            arrivals = np.asarray([r.arrival_us for r in reqs])
            res = batcher.drain(arrivals, ext)
            self.last_results[name] = res
            metrics["models"][name] = res.metrics()

        results = list(self.last_results.values())
        lat = (np.concatenate([r.latencies_us for r in results])
               if results else np.zeros(0))
        comp = (np.concatenate([r.completion_us for r in results])
                if results else np.zeros(0))
        metrics["total"] = latency_metrics(lat, comp)
        metrics["total"]["models"] = len(results)
        return metrics
