"""Server loop: drive request streams against a ``ProgramRegistry``.

No HTTP — a :class:`Request` stream is a list of (model, spike train,
arrival time, stream id) records, which is what a transport layer
would produce anyway. The server groups the stream per model (each
model owns one micro-batch queue), drains every queue under its
:class:`~repro.serve.batcher.BatchPolicy`, and surfaces p50/p99/
throughput/shed/stage metrics as a plain dict.

**Timelines.** Multi-model totals are only meaningful on an explicit
execution timeline, so the server owns one:

* ``timeline="shared"`` (default): ONE serially-busy engine is shared
  by every model — dispatches interleave in global time order (ties
  broken by model-name order), so a batch for model A delays model B
  exactly as it would on one accelerator. Totals are computed on that
  single clock.
* ``timeline="per-engine"``: every model simulates on its own
  engine clock from 0, as if each had a dedicated accelerator; totals
  then read as the union wall-span of genuinely concurrent engines.

(The pre-timeline server simulated per-model clocks but reported the
concatenated totals as if the models had run concurrently — a real
accounting bug for the single-engine deployment it was modeling.)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.batcher import (BatchPolicy, DrainResult, MicroBatcher,
                                 SHED_REASONS, drain_together,
                                 latency_metrics)
from repro.serve.registry import ProgramRegistry

_TIMELINES = ("shared", "per-engine")


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request: a spike train for a named model."""
    model: str
    ext: np.ndarray                  # binary [T, n_inputs]
    arrival_us: float
    stream: int = 0                  # client-stream tag (FIFO per stream)


def _aggregate_totals(results: dict[str, DrainResult],
                      timeline: str) -> dict:
    """Totals over every model's served requests on one declared
    timeline, plus shed / deadline / stage accounting."""
    lats = [r.latencies_us[r.served] for r in results.values()]
    comps = [r.completion_us[r.served] for r in results.values()]
    lat = np.concatenate(lats) if lats else np.zeros(0)
    comp = np.concatenate(comps) if comps else np.zeros(0)
    total = latency_metrics(lat, comp)
    total["models"] = len(results)
    total["timeline"] = timeline
    shed = {name: 0 for name in SHED_REASONS.values()}
    n_req = 0
    stage_arrays: dict[str, list[np.ndarray]] = {
        "queue_wait": [], "batch_fill": [], "pad": [], "compute": []}
    for r in results.values():
        n_req += r.n_requests
        for k, v in r.shed_counts().items():
            shed[k] += v
        stage_arrays["queue_wait"].append(r.queue_wait_us[r.served])
        stage_arrays["batch_fill"].append(r.fill_wait_us[r.served])
        stage_arrays["pad"].append(r.pad_us[r.served])
        stage_arrays["compute"].append(r.compute_us[r.served])
    total["shed"] = shed
    n_shed = sum(shed.values())
    total["shed_frac"] = n_shed / n_req if n_req else 0.0
    total["deadline_misses"] = shed["deadline"]
    total["stages_us"] = {
        k: (float(np.concatenate(v).mean()) if len(lat) else 0.0)
        for k, v in stage_arrays.items()}
    return total


class Server:
    """Drains request streams against the registry, one queue per model.

    policy: default :class:`BatchPolicy`. Per-model overrides resolve
    ``policies[name]`` first, then the policy registered with the
    model (``ProgramRegistry.register(policy=...)``), then ``policy``.
    ``service_model`` (bucket -> us) makes latencies deterministic;
    ``None`` measures real engine calls. ``spec`` (an
    :class:`~repro.core.execution.ExecutionSpec`) routes every model
    through that execution point — e.g. ``ExecutionSpec(mesh="auto")``
    for the owned multi-device runner. ``timeline`` picks the
    multi-model accounting clock (see module docstring).
    ``sharded=``/``mesh=`` are the deprecated pre-spec kwargs.
    """

    def __init__(self, registry: ProgramRegistry, *,
                 policy: BatchPolicy | None = None,
                 policies: dict[str, BatchPolicy] | None = None,
                 service_model=None, spec=None, timeline: str = "shared",
                 sharded: bool | None = None, mesh=None):
        if sharded is not None or mesh is not None:
            if spec is not None:
                raise TypeError("pass spec= OR the deprecated sharded=/"
                                "mesh= kwargs, not both")
            from repro.core.execution import spec_from_legacy_kwargs
            spec = spec_from_legacy_kwargs(sharded=sharded, mesh=mesh,
                                           where="Server", stacklevel=3)
        if timeline not in _TIMELINES:
            raise ValueError(f"timeline must be one of {_TIMELINES}, "
                             f"got {timeline!r}")
        self.registry = registry
        self.policy = policy or BatchPolicy()
        self.policies = dict(policies or {})
        self.service_model = service_model
        self.spec = spec
        self.timeline = timeline
        self.last_results: dict[str, DrainResult] = {}
        # MicroBatchers are reused across serve() calls so the warmed
        # (bucket, T, dtype) cache survives — keyed on the program
        # identity so replacing a model rebuilds its batcher
        self._batchers: dict[str, tuple[int, MicroBatcher]] = {}

    def policy_for(self, name: str) -> BatchPolicy:
        """Per-call override > registry-registered policy > default."""
        if name in self.policies:
            return self.policies[name]
        registered = self.registry.policy(name)
        return registered if registered is not None else self.policy

    def _batcher(self, name: str) -> MicroBatcher:
        program = self.registry.get(name)
        cached = self._batchers.get(name)
        if cached is not None and cached[0] == id(program):
            return cached[1]
        batcher = MicroBatcher(self.policy_for(name),
                               runner=self.registry.runner(name, self.spec),
                               service_model=self.service_model)
        self._batchers[name] = (id(program), batcher)
        return batcher

    @staticmethod
    def _validate_shapes(name: str,
                         pairs: list[tuple[int, Request]]) -> tuple:
        """All requests for one model must agree on [T, n_inputs];
        name the offending request index and stream otherwise."""
        k0, r0 = pairs[0]
        ref = np.asarray(r0.ext).shape
        if len(ref) != 2:
            raise ValueError(
                f"request #{k0} for model {name!r} (stream {r0.stream}) "
                f"has spike-train shape {ref}; expected a 2-D "
                f"[T, n_inputs] array")
        for k, r in pairs[1:]:
            shape = np.asarray(r.ext).shape
            if shape != ref:
                raise ValueError(
                    f"request #{k} for model {name!r} (stream {r.stream}) "
                    f"has spike-train shape {shape}, but request #{k0} "
                    f"(stream {r0.stream}) set [T, n_inputs] = {ref}; all "
                    f"requests for one model must agree")
        return ref

    def serve(self, stream: list[Request]) -> dict:
        """Serve every request; return the metrics dict.

        The stream may interleave models and client streams; within
        each model requests are served FIFO by arrival time (ties keep
        stream order — the sort is stable).
        """
        order = sorted(range(len(stream)),
                       key=lambda k: stream[k].arrival_us)
        by_model: dict[str, list[tuple[int, Request]]] = {}
        for k in order:
            r = stream[k]
            if r.model not in self.registry:
                raise KeyError(f"request for unregistered model "
                               f"{r.model!r}; have {self.registry.names()}")
            by_model.setdefault(r.model, []).append((k, r))

        names = sorted(by_model)           # queue order = tie-break order
        items = []
        for name in names:
            pairs = by_model[name]
            self._validate_shapes(name, pairs)
            ext = np.stack([np.asarray(r.ext) for _, r in pairs])
            arrivals = np.asarray([r.arrival_us for _, r in pairs])
            items.append((self._batcher(name), arrivals, ext))

        if self.timeline == "shared":
            drained = drain_together(items)
        else:
            drained = [b.drain(arr, ext) for b, arr, ext in items]

        self.last_results = dict(zip(names, drained))
        metrics: dict = {"models": {
            name: res.metrics()
            for name, res in self.last_results.items()}}
        metrics["total"] = _aggregate_totals(self.last_results,
                                             self.timeline)
        return metrics
