"""Multi-device data-parallel execution of a compiled ``Program``.

The compiled batched executor (:class:`repro.core.engine_jax
.JaxMappedEngine`) is embarrassingly parallel over the batch axis —
every sample runs the same lowered program on its own spike train, all
in exact int32 arithmetic. :class:`ShardedRunner` exploits that: it
takes the engine's uncompiled step function and wraps it in
``shard_map`` over a jax mesh, sharding the leading batch axis across
the mesh's ``data`` axis (``PartitionSpec('data')`` in and out) and
replicating the lowered program's constant arrays.

Why the result is bit-exact vs the single-device engine:

* each device executes the byte-identical scan on its batch shard —
  there is no cross-sample communication, reduction, or reordering;
* all arithmetic is int32 (deterministic-commit property, paper §4.2),
  so shard boundaries cannot perturb any value;
* ragged batches are handled by **pad-and-mask**: the batch is padded
  with all-zero samples up to the next multiple of the shard count,
  and the pad rows are sliced away (masked) from spikes, potentials,
  and packet counts before stats are computed — zero-input pad samples
  never touch the real rows.

On CPU, CI forces >= 8 virtual devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see the
``serving`` lane); with a single device the mesh degenerates to one
shard and the runner is still exact, so the same tests run everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine_jax import finalize_outputs, normalize_ext_spikes


class ShardedRunner:
    """A ``Program`` compiled for data-parallel execution over a mesh.

    Construction wraps the program's owned engine step function in
    ``shard_map`` + ``jit``; :meth:`run` then serves any batch —
    including ragged ones that do not divide the shard count — with
    outputs bit-exact vs ``program.run(ext)`` on one device.
    """

    def __init__(self, program, mesh=None, *, nu_kernel: bool = True,
                 interpret: bool | None = None):
        if mesh is None:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh()
        if "data" not in mesh.axis_names:
            raise ValueError(f"mesh axes {mesh.axis_names} lack 'data'; "
                             "the batch axis shards over 'data' "
                             "(launch.mesh.make_serving_mesh)")
        self.mesh = mesh
        self.n_shards = int(mesh.shape["data"])
        engine = program.engine(nu_kernel=nu_kernel, interpret=interpret)
        self._n_inputs = engine.lowered.n_inputs
        self._n_internal = engine.lowered.n_internal
        spec = P("data")
        # check_rep=False: the Pallas NU kernel has no replication rule;
        # every output is batch-sharded anyway, nothing is replicated.
        self._run = jax.jit(shard_map(
            engine.step_fn, mesh=mesh,
            in_specs=(spec, spec, spec), out_specs=(spec, spec, spec),
            check_rep=False))

    def padded_size(self, b: int) -> int:
        """Next multiple of the shard count (the pad-and-mask bucket)."""
        d = self.n_shards
        return ((b + d - 1) // d) * d

    def run(self, ext_spikes: np.ndarray
            ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Execute the program on ``ext_spikes`` across the mesh.

        ext_spikes: binary ``[T, n_inputs]`` or ``[B, T, n_inputs]``;
        returns ``(spikes, v_final, stats)`` shaped exactly like the
        single-device engine (pad rows are sliced away before stats).
        """
        ext, squeeze = normalize_ext_spikes(ext_spikes, self._n_inputs)
        b, t = ext.shape[0], ext.shape[1]
        full = self.padded_size(b)
        if full != b:                      # pad: all-zero samples
            pad = np.zeros((full - b, t, self._n_inputs), ext.dtype)
            ext = np.concatenate([ext, pad])
        zeros = jnp.zeros((full, self._n_internal), jnp.int32)
        spikes, v, pkts = self._run(jnp.asarray(ext, jnp.int32),
                                    zeros, zeros)
        # mask: drop the pad rows before any stats are derived
        return finalize_outputs(np.asarray(spikes)[:b], np.asarray(v)[:b],
                                np.asarray(pkts)[:b], squeeze)


def sharded_runner(program, mesh=None, *, nu_kernel: bool = True,
                   interpret: bool | None = None) -> ShardedRunner:
    """Build a :class:`ShardedRunner` for ``program`` (default mesh:
    every device on the ``data`` axis)."""
    return ShardedRunner(program, mesh, nu_kernel=nu_kernel,
                         interpret=interpret)
