"""Multi-device data-parallel execution of a compiled ``Program``.

The compiled batched executor (:class:`repro.core.engine_jax
.JaxMappedEngine`) is embarrassingly parallel over the batch axis —
every sample runs the same lowered program on its own spike train, all
in exact int32 arithmetic. :class:`ShardedRunner` exploits that: it
takes the engine's uncompiled step function and wraps it in
``shard_map`` over a jax mesh, sharding the leading batch axis across
the mesh's ``data`` axis (``PartitionSpec('data')`` in and out) and
replicating the lowered program's constant arrays.

Why the result is bit-exact vs the single-device engine:

* each device executes the byte-identical scan on its batch shard —
  there is no cross-sample communication, reduction, or reordering;
* all arithmetic is int32 (deterministic-commit property, paper §4.2),
  so shard boundaries cannot perturb any value;
* ragged batches are handled by **pad-and-mask**: the batch is padded
  with all-zero samples up to the next multiple of the shard count,
  and the pad rows are sliced away (masked) from spikes, potentials,
  and packet counts before stats are computed — zero-input pad samples
  never touch the real rows.

Tiny batches don't shard well: below ``n_shards * min_shard`` real
samples, per-device dispatch overhead exceeds the parallel win (the
``serve.sharded.dispatch_us`` benchmark row measures it), so
:meth:`ShardedRunner.run` routes such batches through the program's
owned single-device engine — bit-exact by the argument above, just
cheaper. ``min_shard=0`` disables the fallback (conformance tests use
it to force the true shard path at every size).

On CPU, CI forces >= 8 virtual devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see the
``serving`` lane); with a single device the mesh degenerates to one
shard and the runner is still exact, so the same tests run everywhere.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine_jax import finalize_outputs, normalize_ext_spikes
from repro.core.execution import (AUTO_MESH, ExecutionSpec,
                                  spec_from_legacy_kwargs)


class ShardedRunner:
    """A ``Program`` compiled for data-parallel execution over a mesh.

    Construction wraps the program's owned engine step function in
    ``shard_map`` + ``jit``; :meth:`run` then serves any batch —
    including ragged ones that do not divide the shard count — with
    outputs bit-exact vs ``program.run(ext)`` on one device.

    ``spec`` is an :class:`~repro.core.execution.ExecutionSpec`
    (``mesh=None`` means the default serving mesh here); the bare
    ``mesh`` positional and the ``nu_kernel=``/``interpret=`` kwargs
    are the deprecated pre-spec surface.
    """

    def __init__(self, program, mesh=None, *,
                 spec: ExecutionSpec | None = None,
                 nu_kernel: bool | None = None,
                 interpret: bool | None = None, min_shard: int = 1):
        if nu_kernel is not None or interpret is not None:
            if spec is not None:
                raise TypeError("pass spec= OR the deprecated nu_kernel=/"
                                "interpret= kwargs, not both")
            spec = spec_from_legacy_kwargs(
                sharded=True, mesh=mesh, nu_kernel=nu_kernel,
                interpret=interpret, where="ShardedRunner", stacklevel=3)
        elif spec is None:
            spec = ExecutionSpec(mesh=mesh if mesh is not None else AUTO_MESH)
        elif mesh is not None:
            raise TypeError("pass the mesh inside spec=, not alongside it")
        if spec.mesh is None:
            spec = dataclasses.replace(spec, mesh=AUTO_MESH)
        spec = spec.resolve()
        mesh = spec.mesh
        if "data" not in mesh.axis_names:
            raise ValueError(f"mesh axes {mesh.axis_names} lack 'data'; "
                             "the batch axis shards over 'data' "
                             "(launch.mesh.make_serving_mesh)")
        self.spec = spec
        self.mesh = mesh
        self.n_shards = int(mesh.shape["data"])
        self.min_shard = int(min_shard)
        # the per-device engine IS the program's owned single-device
        # engine for this spec — the fallback and the shard path share
        # one compiled scan body
        self._engine = program.engine(spec.single_device())
        self._n_inputs = self._engine.lowered.n_inputs
        self._n_internal = self._engine.lowered.n_internal
        pspec = P("data")
        # check_rep=False: the Pallas NU kernel has no replication rule;
        # every output is batch-sharded anyway, nothing is replicated.
        self._run = jax.jit(
            shard_map(self._engine.step_fn, mesh=mesh,
                      in_specs=(pspec, pspec, pspec),
                      out_specs=(pspec, pspec, pspec), check_rep=False),
            donate_argnums=(1,) if spec.donate else ())
        self._aot: dict[tuple[int, int], object] = {}

    def padded_size(self, b: int) -> int:
        """Next multiple of the shard count (the pad-and-mask bucket)."""
        d = self.n_shards
        return ((b + d - 1) // d) * d

    def _use_fallback(self, b: int) -> bool:
        """True when ``b`` real samples go single-device (see module
        docstring): fewer than ``min_shard`` samples per shard."""
        return b < self.n_shards * self.min_shard

    # -- AOT ----------------------------------------------------------------

    def precompile(self, batch_sizes, timesteps: int
                   ) -> list[tuple[int, int]]:
        """AOT-compile every serving shape, mirroring :meth:`run`'s
        routing: fallback-sized buckets warm the single-device engine,
        the rest warm the sharded scan at their PADDED size (so two
        buckets padding to the same multiple compile once). Returns
        the shapes compiled by this call.
        """
        compiled = []
        for b in batch_sizes:
            b = int(b)
            if self._use_fallback(b):
                compiled.extend(self._engine.precompile([b], timesteps))
                continue
            key = (self.padded_size(b), int(timesteps))
            if key in self._aot:
                continue
            ext = jax.ShapeDtypeStruct((key[0], key[1], self._n_inputs),
                                       jnp.int32)
            st = jax.ShapeDtypeStruct((key[0], self._n_internal), jnp.int32)
            exe = self._run.lower(ext, st, st).compile()
            # one throwaway zero-batch execution warms the dispatch
            # costs outside the executable (state-buffer fills, device
            # placement) — first real request then runs steady-state
            z = lambda s: jnp.zeros(s.shape, s.dtype)
            jax.block_until_ready(exe(z(ext), z(st), z(st)))
            self._aot[key] = exe
            compiled.append(key)
        return compiled

    # -- public API ---------------------------------------------------------

    def run(self, ext_spikes: np.ndarray
            ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Execute the program on ``ext_spikes`` across the mesh.

        ext_spikes: binary ``[T, n_inputs]`` or ``[B, T, n_inputs]``;
        returns ``(spikes, v_final, stats)`` shaped exactly like the
        single-device engine (pad rows are sliced away before stats).
        """
        ext, squeeze = normalize_ext_spikes(ext_spikes, self._n_inputs)
        b, t = ext.shape[0], ext.shape[1]
        if self._use_fallback(b):
            return self._engine.run(ext_spikes)
        full = self.padded_size(b)
        if full != b:                      # pad: all-zero samples
            pad = np.zeros((full - b, t, self._n_inputs), ext.dtype)
            ext = np.concatenate([ext, pad])
        shape = (full, self._n_internal)
        fn = self._aot.get((full, t), self._run)
        # two distinct state buffers: under donation v0/s0 must not alias
        spikes, v, pkts = fn(jnp.asarray(ext, jnp.int32),
                             jnp.zeros(shape, jnp.int32),
                             jnp.zeros(shape, jnp.int32))
        # mask: drop the pad rows before any stats are derived
        return finalize_outputs(np.asarray(spikes)[:b], np.asarray(v)[:b],
                                np.asarray(pkts)[:b], squeeze)


def sharded_runner(program, mesh=None, *, spec: ExecutionSpec | None = None,
                   nu_kernel: bool | None = None,
                   interpret: bool | None = None,
                   min_shard: int = 1) -> ShardedRunner:
    """Build a :class:`ShardedRunner` for ``program`` (default mesh:
    every device on the ``data`` axis)."""
    return ShardedRunner(program, mesh, spec=spec, nu_kernel=nu_kernel,
                         interpret=interpret, min_shard=min_shard)
