"""rwkv6-3b — [ssm] 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay.  [arXiv:2404.05892; hf]

Attention-free linear recurrence: sub-quadratic, runs long_500k.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # d_model / head_dim (bookkeeping only)
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6", head_dim=64, decay_lora=64),
    sub_quadratic=True,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="rwkv6-3b-reduced", n_layers=2, d_model=64, n_heads=2,
        n_kv_heads=2, d_ff=128, vocab_size=256,
        ssm=SSMConfig(kind="rwkv6", head_dim=32, decay_lora=8))
