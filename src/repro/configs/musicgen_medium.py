"""musicgen-medium — [audio] 48L d_model=1536 24H (GQA kv=24, i.e. MHA)
d_ff=6144 vocab=2048 — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]

Backbone only (assignment): the EnCodec frontend is a stub — inputs are
codebook token ids [B, S, K=4] in the delay interleaving pattern; the
backbone embeds each codebook, sums, and predicts K parallel heads.
Sinusoidal positions, LayerNorm, GELU MLP, no RoPE. Full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    partial_rotary=0.0,
    pos_embed="sinusoidal",
    mlp_style="gelu",
    norm_style="layernorm",
    n_codebooks=4,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="musicgen-medium-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64, n_codebooks=2)
