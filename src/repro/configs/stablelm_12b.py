"""stablelm-12b — [dense] 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352.  [hf:stabilityai/stablelm-2-12b; hf]

StableLM-2 style: LayerNorm, partial rotary (25% of head dim), SwiGLU MLP,
qkv biases. Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    qkv_bias=True,
    partial_rotary=0.25,
    rope_theta=10000.0,
    mlp_style="swiglu",
    norm_style="layernorm",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="stablelm-12b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256)
