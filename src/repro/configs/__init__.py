"""Config registry: ``get_config(name)`` / ``get_reduced(name)`` for every
assigned architecture (plus the paper's own SNN hardware configs)."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, SSMConfig
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, smoke_shape

_MODULES = {
    "stablelm-12b": "repro.configs.stablelm_12b",
    "glm4-9b": "repro.configs.glm4_9b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_reduced(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name]).reduced()


def all_cells():
    """All applicable (arch, shape) pairs — the dry-run grid (40 cells)."""
    cells = []
    for a in ARCH_NAMES:
        cfg = get_config(a)
        for s in SHAPES:
            if applicable(cfg, s):
                cells.append((a, s))
    return cells


__all__ = ["ArchConfig", "MLAConfig", "MoEConfig", "SSMConfig", "SHAPES",
           "ShapeSpec", "applicable", "smoke_shape", "ARCH_NAMES",
           "get_config", "get_reduced", "all_cells"]
