"""Assigned input shapes (one set, shared by all 10 LM archs).

`train_4k` / `prefill_32k` lower train_step / prefill_step; `decode_32k` /
`long_500k` lower serve_step (single new token against a cache of seq_len).
`long_500k` requires sub-quadratic attention: run only for archs with
``sub_quadratic=True`` (rwkv6-3b, zamba2-7b), skip the rest (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(arch_cfg, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return bool(arch_cfg.sub_quadratic)
    return True


def smoke_shape(kind: str = "train") -> ShapeSpec:
    """Tiny variant for CPU smoke tests."""
    if kind == "train":
        return ShapeSpec("smoke_train", 32, 2, "train")
    if kind == "prefill":
        return ShapeSpec("smoke_prefill", 32, 2, "prefill")
    return ShapeSpec("smoke_decode", 64, 2, "decode")
