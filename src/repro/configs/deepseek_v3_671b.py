"""deepseek-v3-671b — [moe] 61L d_model=7168 128H (GQA kv=128) d_ff=2048
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]

MLA attention (latent KV cache + weight-absorbed decode), 3 leading dense
layers (d_ff 18432), 61-3 = 58 MoE layers with 256 routed experts (top-8)
plus 1 shared expert (d_ff 2048 each). The MTP head is omitted (training
objective variant, not a systems feature — DESIGN.md §8). Full attention
-> long_500k skipped.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    head_dim=128,
    rope_theta=10000.0,
    mlp_style="swiglu",
    norm_style="rmsnorm",
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, d_ff_shared=2048,
                  n_dense_layers=3, d_ff_dense=18432),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="deepseek-v3-671b-reduced", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=256, head_dim=16,
        # capacity_factor = E/k = no-drop bound, so reduced-config tests can
        # check prefill/decode vs teacher-forced equivalence exactly (with
        # drops, different batch shapes drop different tokens by design)
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=1, d_ff_shared=32,
                      n_dense_layers=1, d_ff_dense=128,
                      capacity_factor=4.0),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16))
