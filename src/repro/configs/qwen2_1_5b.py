"""qwen2-1.5b — [dense] 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA, QKV bias.  [arXiv:2407.10671; hf]

Qwen2: RMSNorm, full rotary, SwiGLU, qkv bias, tied embeddings,
rope_theta=1e6. Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    partial_rotary=1.0,
    rope_theta=1e6,
    mlp_style="swiglu",
    norm_style="rmsnorm",
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="qwen2-1.5b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256)
