"""qwen2-vl-7b — [vlm] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Backbone only (assignment): the ViT frontend is a stub — the M-RoPE
(t, h, w) position triplets [3, B, S] arrive precomputed from the
frontend (``input_specs`` supplies them); patch embeddings enter the
token stream as ids. M-RoPE sections (16, 24, 24) over head_dim/2 = 64.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    partial_rotary=1.0,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    mlp_style="swiglu",
    norm_style="rmsnorm",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-7b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        mrope_sections=(4, 2, 2))
