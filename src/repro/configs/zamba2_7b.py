"""zamba2-7b — [hybrid] 81L d_model=3584 32H (GQA kv=32, i.e. MHA)
d_ff=14336 vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks.
[arXiv:2411.15242; unverified]

81 Mamba-2 layers with ONE shared transformer block (attn + MLP) applied
every ``attn_layer_period`` layers — the paper's time-multiplexed
centralized-unit pattern (DESIGN.md §4). SSM backbone -> sub-quadratic,
runs long_500k (the periodic shared attention attends over the full
context through its KV cache; noted in the roofline).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(kind="mamba2", head_dim=64, d_state=64, d_conv=4,
                  expand=2),
    attn_layer_period=6,
    sub_quadratic=True,
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="zamba2-7b-reduced", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256,
        ssm=SSMConfig(kind="mamba2", head_dim=16, d_state=16, d_conv=4,
                      expand=2),
        attn_layer_period=2)
