"""qwen3-moe-30b-a3b — [moe] 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128e top-8 — 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B; hf]

Qwen3: RMSNorm, qk-norm, head_dim=128, rope_theta=1e6, no shared expert,
per-expert d_ff=768. Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    mlp_style="swiglu",
    norm_style="rmsnorm",
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="qwen3-moe-30b-a3b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=32, vocab_size=256, head_dim=16,
        # E/k capacity: no token drops -> exact prefill/decode equivalence
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=4.0))
