"""glm4-9b — [dense] 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 — RoPE, GQA.  [hf:THUDM/glm-4-9b; hf]

GLM style: RMSNorm, partial rotary (half the head dim — the "2d" GLM RoPE
acts on the first half of each head), SwiGLU, qkv bias. Full attention ->
long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    qkv_bias=True,
    partial_rotary=0.5,
    rope_theta=10000.0,
    mlp_style="swiglu",
    norm_style="rmsnorm",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="glm4-9b-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256)
