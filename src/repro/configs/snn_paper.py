"""SupraSNN's own two networks (paper Table 2) as selectable configs."""
from repro.core.memory_model import HardwareConfig
from repro.snn.models import MNIST_CONFIG, SHD_CONFIG  # noqa: F401


def mnist_scale_random_graph(n_synapses: int = 12000, seed: int = 0):
    """Random graph + hardware at the paper's MNIST scale (784-126,
    16 SPUs) — the shared fixture of the executor acceptance test and
    the engine-speedup benchmark. Returns (graph, HardwareConfig)."""
    from repro.core.graph import random_graph
    g = random_graph(784, 126, n_synapses, seed=seed)
    hw = HardwareConfig(
        n_spus=16, unified_mem_depth=4 * (n_synapses // 16 // 3 + 126),
        concentration=3, weight_bits=4, potential_bits=5,
        max_neurons=910, max_post_neurons=126, clock_mhz=100.0)
    return g, hw

MNIST_HW = HardwareConfig(
    n_spus=16, unified_mem_depth=128, concentration=3, weight_bits=4,
    potential_bits=5, max_neurons=910, max_post_neurons=126,
    clock_mhz=100.0)

SHD_HW = HardwareConfig(
    n_spus=64, unified_mem_depth=256, concentration=3, weight_bits=7,
    potential_bits=12, max_neurons=1020, max_post_neurons=320,
    clock_mhz=100.0)
