"""SupraSNN's own two networks (paper Table 2) as selectable configs."""
from repro.core.memory_model import HardwareConfig
from repro.snn.models import MNIST_CONFIG, SHD_CONFIG  # noqa: F401

MNIST_HW = HardwareConfig(
    n_spus=16, unified_mem_depth=128, concentration=3, weight_bits=4,
    potential_bits=5, max_neurons=910, max_post_neurons=126,
    clock_mhz=100.0)

SHD_HW = HardwareConfig(
    n_spus=64, unified_mem_depth=256, concentration=3, weight_bits=7,
    potential_bits=12, max_neurons=1020, max_post_neurons=320,
    clock_mhz=100.0)
