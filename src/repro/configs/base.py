"""Unified architecture configuration for the assigned-architecture pool.

Every assigned arch gets one file in this package defining an ``ArchConfig``
(exact public numbers) plus a ``reduced()`` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    n_dense_layers: int = 0        # leading dense layers (deepseek-v3: 3)
    d_ff_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "rwkv6"            # "rwkv6" | "mamba2"
    head_dim: int = 64             # rwkv6 head size / mamba2 head dim
    d_state: int = 64              # mamba2 SSM state per head
    d_conv: int = 4                # mamba2 depthwise conv width
    expand: int = 2                # mamba2 inner expansion
    decay_lora: int = 64           # rwkv6 data-dependent-decay LoRA rank


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False                   # qwen3
    partial_rotary: float = 1.0             # fraction of head_dim rotated
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple] = None  # qwen2-vl M-RoPE (t, h, w) pairs
    mlp_style: str = "swiglu"               # swiglu | gelu
    norm_style: str = "rmsnorm"             # rmsnorm | layernorm
    pos_embed: str = "rope"                 # rope | sinusoidal
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_layer_period: int = 0              # zamba2: shared attn every k
    n_codebooks: int = 0                    # musicgen: EnCodec codebooks
    vision_patches: int = 0                 # qwen2-vl: stub patch count
    sub_quadratic: bool = False             # supports long_500k decode
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, l = self.d_model, self.n_layers
        v = self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.n_codebooks:
            emb = self.n_codebooks * v * d * 2
        hd = self.resolved_head_dim
        if self.mla:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
        if self.mlp_style == "swiglu":
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        if self.family == "ssm":
            s = self.ssm
            inner = d * s.expand if s.kind == "mamba2" else d
            blk = (6 * d * inner if s.kind == "rwkv6"
                   else 2 * d * inner + inner * d) + 3 * d * self.d_ff
            return emb + l * blk
        if self.moe:
            mo = self.moe
            moe_mlp = (mo.n_experts * 3 * d * mo.d_ff_expert
                       + mo.n_shared_experts * 3 * d * mo.d_ff_shared
                       + d * mo.n_experts)
            dense_layers = mo.n_dense_layers
            moe_layers = l - dense_layers
            return (emb + moe_layers * (attn + moe_mlp)
                    + dense_layers * (attn + 3 * d * (mo.d_ff_dense or self.d_ff)))
        if self.family == "hybrid":
            s = self.ssm
            inner = d * s.expand
            mamba_blk = (2 * d * inner + inner * d
                         + inner * (2 * s.d_state) + inner)
            n_shared = 1
            shared_blk = attn + mlp_dense
            return emb + l * mamba_blk + n_shared * shared_blk
        return emb + l * (attn + mlp_dense)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.n_params()
        d, l, mo = self.d_model, self.n_layers, self.moe
        full = self.n_params()
        all_experts = (l - mo.n_dense_layers) * mo.n_experts * 3 * d * mo.d_ff_expert
        active = (l - mo.n_dense_layers) * mo.top_k * 3 * d * mo.d_ff_expert
        return full - all_experts + active
