"""chatglm3-6b — [dense] 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d, GQA.  [arXiv:2406.12793; hf]

ChatGLM3: RMSNorm, 2d RoPE (rotary over half the head dim), SwiGLU,
qkv bias. Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    qkv_bias=True,
    partial_rotary=0.5,
    rope_theta=10000.0,
    mlp_style="swiglu",
    norm_style="rmsnorm",
)


def reduced() -> ArchConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, name="chatglm3-6b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256)
