"""Synthetic SHD-like dataset: 700 input channels (cochlear model bins),
spike trains over T timesteps, 20 classes (digits 0-9, English + German).

Each class is a characteristic spatio-temporal activity pattern: a set of
formant-like ridges sweeping across channels over time, with per-sample
jitter — structurally similar to the real Spiking Heidelberg Digits.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

N_CHANNELS = 700
N_CLASSES = 20


def _class_proto(cls: int, rng: np.random.Generator, timesteps: int):
    """Deterministic per-class ridge parameters."""
    r = np.random.default_rng(1234 + cls)
    n_ridges = 3
    starts = r.uniform(0.1, 0.9, n_ridges) * N_CHANNELS
    slopes = r.uniform(-2.0, 2.0, n_ridges) * N_CHANNELS / timesteps
    widths = r.uniform(15, 45, n_ridges)
    gains = r.uniform(0.25, 0.5, n_ridges)
    return starts, slopes, widths, gains


def synthetic_shd(n_train: int = 512, n_test: int = 256, timesteps: int = 100,
                  seed: int = 0):
    """Returns (spk_train [N,T,700] uint8, y_train, spk_test, y_test)."""

    def make(n, salt):
        rng = np.random.default_rng(seed + salt)
        ys = rng.integers(0, N_CLASSES, n).astype(np.int32)
        t = np.arange(timesteps, dtype=np.float32)[:, None]        # [T,1]
        ch = np.arange(N_CHANNELS, dtype=np.float32)[None, :]      # [1,C]
        out = np.zeros((n, timesteps, N_CHANNELS), np.uint8)
        for i, y in enumerate(ys):
            starts, slopes, widths, gains = _class_proto(int(y), rng, timesteps)
            rate = np.zeros((timesteps, N_CHANNELS), np.float32)
            for s0, sl, w, g in zip(starts, slopes, widths, gains):
                center = s0 + sl * t + rng.normal(0, 6.0)          # jittered
                rate += g * np.exp(-0.5 * ((ch - center) / w) ** 2)
            rate += 0.01  # background activity
            out[i] = (rng.random((timesteps, N_CHANNELS)) < rate).astype(np.uint8)
        return out, ys

    xtr, ytr = make(n_train, 1)
    xte, yte = make(n_test, 2)
    return xtr, ytr, xte, yte


def shd_batches(xs: np.ndarray, ys: np.ndarray, batch: int, seed: int = 0
                ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yields ([T, B, 700] float32 spikes, [B] labels) — time-major."""
    rng = np.random.default_rng(seed)
    n = len(xs)
    while True:
        idx = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            j = idx[i:i + batch]
            yield (xs[j].transpose(1, 0, 2).astype(np.float32), ys[j])
