"""MNIST pipeline. The container is offline, so by default we generate a
*synthetic* MNIST-like dataset: 28x28 grayscale digits rendered procedurally
(strokes per digit class + random affine jitter + noise). ``load_mnist``
picks up the real IDX files if they exist under ``data_dir``.
"""
from __future__ import annotations

import gzip
import os
import struct
from typing import Iterator

import numpy as np

# ---------------------------------------------------------------------------
# Procedural digit rendering: each digit is a polyline set on a 28x28 canvas.
# ---------------------------------------------------------------------------

# Stroke control points in a [0,1]^2 box (x right, y down).
_DIGIT_STROKES: dict[int, list[list[tuple[float, float]]]] = {
    0: [[(0.5, 0.1), (0.8, 0.3), (0.8, 0.7), (0.5, 0.9), (0.2, 0.7),
         (0.2, 0.3), (0.5, 0.1)]],
    1: [[(0.35, 0.25), (0.55, 0.1), (0.55, 0.9)]],
    2: [[(0.2, 0.3), (0.4, 0.1), (0.7, 0.15), (0.75, 0.4), (0.3, 0.7),
         (0.2, 0.9), (0.8, 0.9)]],
    3: [[(0.25, 0.15), (0.7, 0.15), (0.45, 0.45), (0.75, 0.65), (0.6, 0.9),
         (0.25, 0.85)]],
    4: [[(0.65, 0.9), (0.65, 0.1), (0.2, 0.6), (0.85, 0.6)]],
    5: [[(0.75, 0.1), (0.3, 0.1), (0.25, 0.45), (0.65, 0.45), (0.75, 0.7),
         (0.55, 0.9), (0.25, 0.85)]],
    6: [[(0.7, 0.1), (0.35, 0.35), (0.25, 0.7), (0.5, 0.9), (0.75, 0.7),
         (0.55, 0.5), (0.3, 0.6)]],
    7: [[(0.2, 0.12), (0.8, 0.12), (0.45, 0.9)]],
    8: [[(0.5, 0.1), (0.75, 0.25), (0.5, 0.48), (0.25, 0.25), (0.5, 0.1)],
        [(0.5, 0.48), (0.8, 0.7), (0.5, 0.92), (0.2, 0.7), (0.5, 0.48)]],
    9: [[(0.7, 0.35), (0.45, 0.45), (0.3, 0.25), (0.5, 0.1), (0.7, 0.25),
         (0.7, 0.55), (0.55, 0.9)]],
}


def _render(digit: int, rng: np.random.Generator, size: int = 28) -> np.ndarray:
    img = np.zeros((size, size), np.float32)
    # random affine jitter
    ang = rng.uniform(-0.25, 0.25)
    sc = rng.uniform(0.8, 1.1)
    dx, dy = rng.uniform(-2.0, 2.0, size=2)
    ca, sa = np.cos(ang), np.sin(ang)
    thick = rng.uniform(0.9, 1.5)
    for stroke in _DIGIT_STROKES[digit]:
        pts = np.array(stroke, np.float32)
        # jitter control points slightly
        pts = pts + rng.normal(0, 0.015, pts.shape).astype(np.float32)
        # to pixel coords with affine
        xy = (pts - 0.5) * sc
        xr = xy[:, 0] * ca - xy[:, 1] * sa
        yr = xy[:, 0] * sa + xy[:, 1] * ca
        px = (xr + 0.5) * (size - 8) + 4 + dx
        py = (yr + 0.5) * (size - 8) + 4 + dy
        # draw line segments with supersampling
        for i in range(len(px) - 1):
            n = max(int(np.hypot(px[i + 1] - px[i], py[i + 1] - py[i]) * 3), 2)
            ts = np.linspace(0, 1, n)
            xs = px[i] + ts * (px[i + 1] - px[i])
            ys = py[i] + ts * (py[i + 1] - py[i])
            for x, y in zip(xs, ys):
                x0, y0 = int(np.floor(x)), int(np.floor(y))
                for oy in (0, 1):
                    for ox in (0, 1):
                        xi, yi = x0 + ox, y0 + oy
                        if 0 <= xi < size and 0 <= yi < size:
                            w = max(0.0, thick - np.hypot(x - xi, y - yi))
                            img[yi, xi] = max(img[yi, xi], min(1.0, w))
    img += rng.normal(0, 0.02, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def synthetic_mnist(n_train: int = 2048, n_test: int = 512, seed: int = 0
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (x_train [N,784], y_train, x_test, y_test) with x in [0,1]."""
    rng = np.random.default_rng(seed)

    def make(n, salt):
        r = np.random.default_rng(seed + salt)
        ys = r.integers(0, 10, n)
        xs = np.stack([_render(int(y), r).reshape(-1) for y in ys])
        return xs.astype(np.float32), ys.astype(np.int32)

    xtr, ytr = make(n_train, 1)
    xte, yte = make(n_test, 2)
    return xtr, ytr, xte, yte


def load_mnist(data_dir: str = "/root/data/mnist", **synth_kw):
    """Real MNIST if IDX files are present, else the synthetic generator."""
    names = ["train-images-idx3-ubyte", "train-labels-idx1-ubyte",
             "t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte"]

    def read_idx(path):
        op = gzip.open if path.endswith(".gz") else open
        with op(path, "rb") as f:
            magic = struct.unpack(">I", f.read(4))[0]
            ndim = magic & 0xFF
            dims = struct.unpack(">" + "I" * ndim, f.read(4 * ndim))
            return np.frombuffer(f.read(), np.uint8).reshape(dims)

    paths = []
    for n in names:
        for cand in (os.path.join(data_dir, n), os.path.join(data_dir, n + ".gz")):
            if os.path.exists(cand):
                paths.append(cand)
                break
    if len(paths) == 4:
        xtr = read_idx(paths[0]).reshape(-1, 784).astype(np.float32) / 255.0
        ytr = read_idx(paths[1]).astype(np.int32)
        xte = read_idx(paths[2]).reshape(-1, 784).astype(np.float32) / 255.0
        yte = read_idx(paths[3]).astype(np.int32)
        return xtr, ytr, xte, yte
    return synthetic_mnist(**synth_kw)


def mnist_batches(xs: np.ndarray, ys: np.ndarray, batch: int, seed: int = 0
                  ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(xs)
    while True:
        idx = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            j = idx[i:i + batch]
            yield xs[j], ys[j]
