from repro.data.mnist import synthetic_mnist, mnist_batches, load_mnist
from repro.data.shd import synthetic_shd, shd_batches

__all__ = ["synthetic_mnist", "mnist_batches", "load_mnist",
           "synthetic_shd", "shd_batches"]
